"""One benchmark per paper table/figure (calibrated-simulator reproductions
plus a real-execution micro-benchmark of the scheduler runtime).

Fig. 1e  chunk-size -> effective accelerator throughput curve
Table 1  tuned G per platform (chunk search on the platform curve)
Fig. 2   Dynamic vs Bulk-Oracle, 3+1 / 4+1, time & energy & EDP
Fig. 5   overhead breakdown O_sp/O_hd/O_kl/O_dh/O_td
Fig. 6   Dynamic Pri
Fig. 7   big.LITTLE 3+1..8+1 with Pri
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (EXYNOS, HASWELL, IVY, PLATFORMS, SimConfig,
                        bulk_oracle, occupancy_seed, run_config, search_chunk,
                        simulate)


def rows_fig1e():
    """chunk -> effective throughput (exec + transfers + launch), Haswell."""
    plat = HASWELL
    out = []
    for chunk in (320, 640, 1280, 2048, 4096, 8192, 16384):
        lam = plat.accel(chunk)
        t = plat.t_hd_ms + plat.t_kl_ms + chunk / lam + plat.t_dh_ms
        out.append((f"fig1e/chunk_{chunk}", t * 1e3 / chunk,
                    f"eff_thpt={chunk / t:.1f}it/ms"))
    return out


def rows_table1():
    out = []
    for name, plat in PLATFORMS.items():
        seed = occupancy_seed(20, 16)      # paper's Haswell-style seed

        def eff(chunk):
            t = plat.t_hd_ms + plat.t_kl_ms + chunk / plat.accel(chunk) \
                + plat.t_dh_ms
            return chunk / t

        tr = search_chunk(eff, seed, multiples=64)
        out.append((f"table1/G_{name}", 0.0,
                    f"G={tr.best_chunk};paper={plat.G_opt}"))
    return out


def rows_fig2():
    out = []
    for name, plat in PLATFORMS.items():
        base = bulk_oracle(plat, "3+1", timesteps=15)
        for lbl in ("3+1", "4+1"):
            b = bulk_oracle(plat, lbl, timesteps=15)
            d = run_config(plat, lbl, timesteps=15)
            out.append((f"fig2/{name}/bulk_{lbl}", b.time_ms * 1e3 / 15,
                        f"t={b.time_ms / base.time_ms:.3f};"
                        f"E={b.energy.total_j / base.energy.total_j:.3f};"
                        f"EDP={b.edp / base.edp:.3f}"))
            out.append((f"fig2/{name}/dynamic_{lbl}", d.time_ms * 1e3 / 15,
                        f"t={d.time_ms / base.time_ms:.3f};"
                        f"E={d.energy.total_j / base.energy.total_j:.3f};"
                        f"EDP={d.edp / base.edp:.3f}"))
    return out


def rows_fig5():
    out = []
    for name, plat in PLATFORMS.items():
        for lbl in ("3+1", "4+1"):
            for pri in (False, True):
                r = run_config(plat, lbl, priority=pri, timesteps=15)
                tag = "pri" if pri else "dyn"
                ov = r.overheads
                out.append((
                    f"fig5/{name}/{tag}_{lbl}", r.time_ms * 1e3 / 15,
                    f"O_sp={ov['O_sp']:.4f};O_hd={ov['O_hd']:.4f};"
                    f"O_kl={ov['O_kl']:.4f};O_dh={ov['O_dh']:.4f};"
                    f"O_td={ov['O_td']:.4f}"))
    return out


def rows_fig6():
    out = []
    for name, plat in PLATFORMS.items():
        d = run_config(plat, "4+1", timesteps=75)
        p = run_config(plat, "4+1", priority=True, timesteps=75)
        a = run_config(plat, "4+1", async_depth=2, timesteps=75)
        out.append((f"fig6/{name}/pri_vs_dyn", p.time_ms * 1e3 / 75,
                    f"dt={1 - p.time_ms / d.time_ms:.3f};"
                    f"dE={1 - p.energy.total_j / d.energy.total_j:.3f};"
                    f"dEDP={1 - p.edp / d.edp:.3f}"))
        out.append((f"fig6/{name}/async2_vs_dyn", a.time_ms * 1e3 / 75,
                    f"dt={1 - a.time_ms / d.time_ms:.3f};"
                    f"dEDP={1 - a.edp / d.edp:.3f}"))
    return out


def rows_fig7():
    plat = EXYNOS
    out = []
    base = run_config(plat, "4+1", timesteps=75)
    for lbl in ("3+1", "4+1", "7+1", "8+1"):
        for pri in (False, True):
            for pin in ("big", "little"):
                r = run_config(plat, lbl, priority=pri, host_pin=pin,
                               timesteps=75)
                tag = ("pri-" if pri else "") + \
                    ("a7" if pin == "little" else "a15")
                out.append((
                    f"fig7/{tag}_{lbl}", r.time_ms * 1e3 / 75,
                    f"t={r.time_ms / base.time_ms:.3f};"
                    f"E={r.energy.total_j / base.energy.total_j:.3f};"
                    f"EDP={r.edp / base.edp:.3f}"))
    return out


def rows_realexec():
    """Real-execution scheduler micro-benchmark (SleepExecutor devices):
    measures the runtime's own dispatch overheads on this host."""
    from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                            SleepExecutor)
    groups = {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=512,
                           init_throughput=400_000),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=100_000,
                          min_chunk=8),
    }
    execs = {"accel": SleepExecutor(rate=400_000),
             "cpu0": SleepExecutor(rate=100_000)}
    s = DynamicScheduler(groups, execs, alpha=0.5)
    t0 = time.monotonic()
    res = s.run(0, 50_000)
    wall = time.monotonic() - t0
    ov = res.overheads["accel"]
    n = max(ov["n_chunks"], 1)
    return [("realexec/scheduler_50k", wall * 1e6 / n,
             f"O_sp={ov['O_sp']:.4f};O_td={ov['O_td']:.4f};"
             f"chunks={int(n)}")]


ALL = [rows_fig1e, rows_table1, rows_fig2, rows_fig5, rows_fig6, rows_fig7,
       rows_realexec]
