"""Adaptive policy vs. static baselines: decision stability, tail delay,
and the sharded λ-tracker's completion-path lock cost.

Four experiments, all deterministic (seeded RNGs, virtual clocks for the
admission sims, zero-service SleepExecutors for the runtime ones):

  * square_wave / heavy_tail — an admission gate fed synthetic arrival
    patterns on a virtual clock, drained at exact capacity. Measures
    ADMIT↔DEFER decision flips (oscillation) and the p99 *actual* queue
    delay of admitted jobs, static (point-sample) vs. adaptive (windowed
    hysteresis). The adaptive gate should flip less and keep the
    admitted-tail delay lower on both patterns.
  * rebalance — a straggler report flapping around the detection
    threshold, applied to the gate every tick. Measures applied derate
    changes: the cooldown should cut oscillation by ~the flap/cooldown
    ratio without ever starving a persistent change.
  * completion_lock — the real threaded runtime at 8 workers with the
    sharded ThroughputTracker vs. the single-lock baseline injected into
    the same scheduler. Measures the *tracker's* completion-path lock
    wait (the shared-lock cost PR 8 eliminates) with full work-
    conservation checks, plus a single-worker ``chunk_mode="paper"``
    bit-compatibility cross-check: both trackers must produce the
    identical chunk schedule.

Run:  PYTHONPATH=src python -m benchmarks.run --only adaptive_policy
      PYTHONPATH=src python -m benchmarks.adaptive_policy
"""
from __future__ import annotations

import random
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        LockedThroughputTracker, ScheduleResult,
                        SleepExecutor)
from repro.policy import AdaptivePolicy
from repro.queue import Job, JobState
from repro.queue.admission import AdmissionController, Decision
from repro.queue.manager import QueueManager

CAPACITY = 100.0                     # items/s the simulated fleet serves
SLO_S = 0.5                          # delay band edge: 50-item backlog
DT = 0.02                            # virtual tick
SIM_S = 8.0
QUICK_SIM_S = 2.0
LOCK_ITEMS = 120_000
QUICK_LOCK_ITEMS = 12_000


# ---------------------------------------------------------------------------
# admission simulation on a virtual clock
# ---------------------------------------------------------------------------

def _arrivals_square(t: float, rng: random.Random) -> List[int]:
    """1s ON at 2.5× capacity / 1s OFF: the backlog slams into the SLO
    band edge a third of the way through each burst and hovers there —
    the point-sample gate's worst case (admit/defer flapping)."""
    if int(t) % 2 == 0:
        return [1] * 5                        # 5 jobs/tick = 250 items/s
    return []


def _arrivals_heavy(t: float, rng: random.Random) -> List[int]:
    """Poisson-ish arrivals with Pareto job sizes (~1.3× capacity on
    average) plus a trickle of small jobs: heavy-tailed lumps slam the
    backlog through the band edge while the trickle keeps sampling it."""
    out = []
    if rng.random() < 0.9:                    # ~45 lumps/s
        out.append(min(40, max(1, int(rng.paretovariate(1.2)))))
    if rng.random() < 0.5:                    # ~25 small jobs/s
        out.append(1)
    return out


def _sim_admission(pattern, adaptive: bool, sim_s: float) \
        -> Tuple[float, float, int, Dict[str, int]]:
    """Returns (p99 queue delay, mean queue delay, decision flips,
    counts) over served jobs. Deferred jobs are shed (the band's purpose
    is to keep them off the queue). The drain is completion-based fluid
    service at exactly CAPACITY items/s: a job leaves the queue only
    once capacity has had time to cover it, so it stays in
    ``backlog_items`` until then and the gate's projection is exact —
    negative-credit drains would let the gate undercount committed
    work and smear delays past the SLO for both modes."""
    t = [0.0]
    q = QueueManager()
    policy = AdaptivePolicy(window_s=1.0, spike_threshold=3.0,
                            cooldown_s=1.0, clock=lambda: t[0]) \
        if adaptive else None
    adm = AdmissionController(q, tracker=None, slo_delay_s=SLO_S,
                              clock=lambda: t[0], policy=policy)
    adm.on_group_join("fleet", CAPACITY)
    rng = random.Random(1234)
    admitted_at: Dict[str, float] = {}
    delays: List[float] = []
    flips = 0
    last: Optional[bool] = None
    credit = 0.0
    counts = {"admitted": 0, "deferred": 0, "rejected": 0}
    while t[0] < sim_s:
        for items in pattern(t[0], rng):
            job = Job(items=items)
            dec = adm.admit(job)
            counts[{Decision.ADMIT: "admitted",
                    Decision.DEFER: "deferred",
                    Decision.REJECT: "rejected"}[dec.decision]] += 1
            is_admit = dec.decision is Decision.ADMIT
            if is_admit:
                admitted_at[job.job_id] = t[0]
            if last is not None and is_admit != last:
                flips += 1
            last = is_admit
        head = q.peek()
        if head is None:
            credit = 0.0                      # idle: no banked capacity
        else:
            credit += CAPACITY * DT
            while head is not None and credit >= head.items:
                credit -= head.items
                q.pop()
                q.mark_running(head)
                q.mark_finished(head, JobState.DONE)
                delays.append(t[0] - admitted_at.pop(head.job_id))
                head = q.peek()
        t[0] += DT
    delays.sort()
    p99 = delays[min(len(delays) - 1,
                     int(0.99 * len(delays)))] if delays else 0.0
    mean = sum(delays) / len(delays) if delays else 0.0
    return p99, mean, flips, counts


def _sim_rows(name: str, pattern, sim_s: float) \
        -> List[Tuple[str, float, str]]:
    out = []
    results = {}
    for label, adaptive in (("static", False), ("adaptive", True)):
        p99, mean, flips, counts = _sim_admission(pattern, adaptive, sim_s)
        results[label] = (p99, flips)
        out.append((
            f"adaptive_policy/{name}/{label}",
            p99 * 1e6,      # virtual-µs p99 queue delay of served jobs
            f"flips={flips};mean_delay_ms={mean * 1e3:.1f};"
            f"admitted={counts['admitted']};"
            f"deferred={counts['deferred']};"
            f"rejected={counts['rejected']}"))
    if results["adaptive"][0] > results["static"][0] or \
            results["adaptive"][1] >= results["static"][1]:
        raise RuntimeError(
            f"adaptive_policy/{name}: adaptive gate must beat static on "
            f"p99 and flips, got {results}")
    return out


# ---------------------------------------------------------------------------
# rebalance oscillation
# ---------------------------------------------------------------------------

def _sim_rebalance(adaptive: bool, sim_s: float) -> Tuple[int, Dict]:
    """A group flapping around the straggler threshold: reported derated
    on even ticks, recovered on odd ticks, every 0.1 virtual seconds."""
    t = [0.0]
    q = QueueManager()
    policy = AdaptivePolicy(cooldown_s=1.0, clock=lambda: t[0]) \
        if adaptive else None
    adm = AdmissionController(q, tracker=None, slo_delay_s=SLO_S,
                              clock=lambda: t[0], policy=policy)
    adm.on_group_join("a", CAPACITY)
    adm.on_group_join("b", CAPACITY)
    changes, i = 0, 0
    last = adm.derate("a")
    while t[0] < sim_s:
        adm.update_stragglers({"a": 0.45} if i % 2 == 0 else {})
        cur = adm.derate("a")
        if cur != last:
            changes += 1
            last = cur
        i += 1
        t[0] += 0.1
    stats = policy.stats() if policy is not None else {}
    return changes, stats


def _rebalance_rows(sim_s: float) -> List[Tuple[str, float, str]]:
    out = []
    for label, adaptive in (("static", False), ("adaptive", True)):
        changes, stats = _sim_rebalance(adaptive, sim_s)
        derived = f"applied_changes={changes}"
        if stats:
            derived += (f";suppressed={int(stats['rebalances_suppressed'])}"
                        f";applied={int(stats['rebalances'])}")
        # the metric IS the oscillation count (µs column reused)
        out.append((f"adaptive_policy/rebalance/{label}",
                    float(changes), derived))
    if adaptive and changes == 0:
        raise RuntimeError("cooldown starved every rebalance")
    return out


# ---------------------------------------------------------------------------
# completion-path tracker lock cost on the real runtime
# ---------------------------------------------------------------------------

def _build(n_workers: int, chunk_mode: str, tracker_cls) -> DynamicScheduler:
    groups = {
        f"g{i}": GroupSpec(f"g{i}", DeviceKind.BIG, init_throughput=1.0,
                           min_chunk=8)
        for i in range(n_workers)}
    execs = {name: SleepExecutor(rate=float("inf")) for name in groups}
    sched = DynamicScheduler(groups, execs, alpha=0.5, base_quantum=64,
                             chunk_mode=chunk_mode)
    if tracker_cls is not None:
        sched.tracker = tracker_cls(sched.alpha)   # before start(): the
    return sched                                   # partitioner binds it


def _check(res: ScheduleResult, items: int, label: str) -> None:
    if res.iterations != items:
        raise RuntimeError(f"{label}: covered {res.iterations}/{items}")
    if sum(res.per_group_items.values()) != res.iterations:
        raise RuntimeError(f"{label}: per-group accounting mismatch")
    covered = sum(r.token.chunk.size for r in res.records)
    if covered != res.iterations:
        raise RuntimeError(f"{label}: chunks cover {covered}")


def _paper_identity_check(items: int) -> None:
    """Single worker, chunk_mode="paper": the sharded tracker must yield
    the bit-identical schedule the locked tracker does."""
    sig = {}
    for label, cls in (("sharded", None), ("locked", LockedThroughputTracker)):
        sched = _build(1, "paper", cls)
        res = sched.run(0, items)
        sched.shutdown()
        _check(res, items, f"adaptive_policy/paper_identity/{label}")
        sig[label] = (res.iterations, res.per_group_items,
                      [(r.token.chunk.begin, r.token.chunk.end)
                       for r in res.records])
    if sig["sharded"] != sig["locked"]:
        raise RuntimeError(
            "paper-mode schedule diverged between sharded and locked "
            "trackers (bit-compatibility broken)")


def _lock_rows(items: int) -> List[Tuple[str, float, str]]:
    _paper_identity_check(max(1000, items // 10))
    out = []
    for label, cls in (("sharded", None),
                       ("locked", LockedThroughputTracker)):
        sched = _build(8, "range", cls)
        res = sched.run(0, items)
        tracker = sched.tracker
        sched.shutdown()
        _check(res, items, f"adaptive_policy/completion_lock/{label}")
        lock = tracker.contention_stats()
        host = sum((r.tc2 - r.tc1) + max(r.tc3 - r.tg5, 0.0)
                   for r in res.records) / len(res.records)
        out.append((
            f"adaptive_policy/completion_lock/{label}/w8",
            lock["lock_wait_s"] * 1e6,
            f"lock_acquires={int(lock['lock_acquires'])};"
            f"host_us_per_chunk={host * 1e6:.3f};"
            f"chunks={len(res.records)};items={items}"))
    return out


# ---------------------------------------------------------------------------

def _rows(sim_s: float, items: int) -> List[Tuple[str, float, str]]:
    out: List[Tuple[str, float, str]] = []
    out += _sim_rows("square_wave", _arrivals_square, sim_s)
    out += _sim_rows("heavy_tail", _arrivals_heavy, sim_s)
    out += _rebalance_rows(sim_s)
    out += _lock_rows(items)
    return out


def rows_adaptive_policy() -> List[Tuple[str, float, str]]:
    return _rows(SIM_S, LOCK_ITEMS)


def rows_adaptive_policy_quick() -> List[Tuple[str, float, str]]:
    """Small profile for scripts/smoke.sh — same checks, tiny sizes."""
    return _rows(QUICK_SIM_S, QUICK_LOCK_ITEMS)


ALL = [rows_adaptive_policy]
QUICK = [rows_adaptive_policy_quick]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for fn in ALL:
        for name, us, derived in fn():
            print(f"{name},{us:.3f},{derived}")
