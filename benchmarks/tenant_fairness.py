"""Tenant fairness & isolation: DWRR sharded drain vs. tenant-blind queue.

Three questions, all on deterministic SleepExecutors (the numbers
characterize the arbitration layer, not model compute; aggregate capacity
is ACCEL_RATE + CPU_RATE items/s):

  * weighted fairness — two tenants with a 10:1 weight skew, both kept
    backlogged through the whole window: does each tenant's drained-items
    share match its weight share? Reported as Jain's fairness index over
    the weight-normalized allocations x_t = items_t / weight_t
    (J = (Σx)²/(n·Σx²); 1.0 = perfectly weighted-fair). The tenant-blind
    global queue drains FIFO → ~1:1 shares → J collapses toward 0.6.

  * per-tenant p95 queue delay at 0.9 offered load with arrivals split
    10:1 — both tenants inside the envelope stay fast.

  * victim isolation — an underloaded interactive tenant (5% of
    capacity, weight 5) vs. a hostile batch tenant that dumps a backlog
    many seconds deep at t0. Victim p95 queue delay is measured isolated,
    under the burst with the DWRR sharded queue, and under the burst with
    the tenant-blind queue (where victim jobs queue behind the entire
    burst and the delay grows with backlog depth — unbounded in the
    limit). Jobs still waiting at window end count their age as a
    censored lower-bound delay, so the blind number cannot flatter
    itself.

Run:  PYTHONPATH=src python -m benchmarks.run --only tenant_fairness
      PYTHONPATH=src python -m benchmarks.tenant_fairness
"""
from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Dict, List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.queue import (Job, JobService, JobState, QueueManager,
                         percentiles)
from repro.tenancy import (ShardedQueueManager, TenantAccountant,
                           TenantRegistry)

clock = time.monotonic

ACCEL_RATE = 20_000.0
CPU_RATE = 5_000.0
CAPACITY = ACCEL_RATE + CPU_RATE
JOB_ITEMS = 100
QUANTUM = 64


def _make_scheduler() -> DynamicScheduler:
    specs = {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=512,
                           init_throughput=ACCEL_RATE),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=CPU_RATE,
                          min_chunk=8),
    }
    execs = {"accel": SleepExecutor(rate=ACCEL_RATE),
             "cpu0": SleepExecutor(rate=CPU_RATE)}
    return DynamicScheduler(specs, execs)


def jain_index(xs: List[float]) -> float:
    if not xs or all(x == 0.0 for x in xs):
        return 0.0
    return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))


# ---------------------------------------------------------------------------
# weighted fairness under saturation, DWRR vs. tenant-blind
# ---------------------------------------------------------------------------

def _saturated_shares(sharded: bool,
                      window_s: float = 1.0) -> Tuple[Dict[str, int], int]:
    """Both tenants pre-backlogged past the window; drained items per
    tenant measured over finalized batches inside the window."""
    reg = TenantRegistry.parse("gold:weight=10,bronze:weight=1")
    queue = ShardedQueueManager(reg, quantum=QUANTUM) if sharded \
        else QueueManager()
    acct = TenantAccountant(reg)
    service = JobService(_make_scheduler, queue=queue, accountant=acct,
                         batch_jobs=8, poll_s=0.002)
    # ~2 windows of backlog each so neither shard empties mid-window;
    # submissions interleave so the blind baseline's FIFO drains ~1:1
    # (its fairest possible order) rather than whoever enqueued first
    per_tenant = int(2.0 * window_s * CAPACITY)
    for _ in range(per_tenant // JOB_ITEMS):
        service.submit(Job(items=JOB_ITEMS, tenant="gold"))
        service.submit(Job(items=JOB_ITEMS, tenant="bronze"))
    service.start()
    time.sleep(window_s)
    service.close()
    items = {t: u["items"] for t, u in acct.snapshot().items()}
    leftover = queue.backlog_items()
    assert leftover > 0, "window outlived the backlog; grow per_tenant"
    return items, leftover


def rows_weighted_fairness() -> List[Tuple[str, float, str]]:
    reg_weights = {"gold": 10.0, "bronze": 1.0}
    out = []
    for mode, sharded in (("dwrr", True), ("blind", False)):
        items, _ = _saturated_shares(sharded)
        xs = [items.get(t, 0) / w for t, w in reg_weights.items()]
        jain = jain_index(xs)
        total = sum(items.values())
        shares = ";".join(f"{t}={items.get(t, 0) / max(total, 1):.3f}"
                          for t in reg_weights)
        out.append((f"tenant_fairness/jain_{mode}", jain * 1e6,
                    f"jain={jain:.4f};{shares};skew=10:1;load=saturated"))
    return out


# ---------------------------------------------------------------------------
# per-tenant p95 queue delay at 0.9 offered load, arrivals split 10:1
# ---------------------------------------------------------------------------

def rows_offered_load(window_s: float = 1.2) -> List[Tuple[str, float, str]]:
    reg = TenantRegistry.parse("gold:weight=10,bronze:weight=1")
    queue = ShardedQueueManager(reg, quantum=QUANTUM)
    acct = TenantAccountant(reg)
    service = JobService(_make_scheduler, queue=queue, accountant=acct,
                         batch_jobs=8, poll_s=0.002)
    service.start()
    jobs_per_s = 0.9 * CAPACITY / JOB_ITEMS
    gap = 1.0 / jobs_per_s
    n = int(jobs_per_s * window_s)
    try:
        for i in range(n):
            # 10:1 arrival split mirrors the weight skew
            tenant = "bronze" if i % 11 == 0 else "gold"
            service.submit(Job(items=JOB_ITEMS, tenant=tenant))
            time.sleep(gap)
        deadline = clock() + 30.0
        while clock() < deadline and queue.depth() > 0:
            time.sleep(0.01)
    finally:
        service.close()
    out = []
    for tenant, usage in acct.snapshot().items():
        p95 = usage["queue_delay_s"]["p95"]
        out.append((f"tenant_fairness/p95_delay_{tenant}", p95 * 1e6,
                    f"p95={p95 * 1e3:.2f}ms;items={usage['items']};"
                    f"load=0.9;split=10:1"))
    return out


# ---------------------------------------------------------------------------
# victim isolation under a hostile burst
# ---------------------------------------------------------------------------

VICTIM_JOBS = 24
VICTIM_BURST = 4                      # jobs per mini-burst (interactive)
VICTIM_GAP_S = 0.16                   # ≈5% of capacity offered
HOSTILE_JOBS = 400                    # × JOB_ITEMS ≈ 1.6 s of capacity


def _victim_run(queue, hostile: bool) -> Dict[str, float]:
    """Victim p95 queue delay; jobs not yet started at window end count
    their age (censored lower bound). Single-job batches keep the
    pipeline-slot granularity (the floor any arrival pays while slots
    are busy) at one job's service time for every mode."""
    service = JobService(_make_scheduler, queue=queue, batch_jobs=1,
                         poll_s=0.002)
    service.start()
    victims: List[Job] = []
    try:
        if hostile:
            for _ in range(HOSTILE_JOBS):
                service.submit(Job(items=JOB_ITEMS, tenant="hostile"))
        for i in range(VICTIM_JOBS):
            job = Job(items=JOB_ITEMS, tenant="victim")
            victims.append(job)
            service.submit(job)
            if (i + 1) % VICTIM_BURST == 0:
                time.sleep(VICTIM_GAP_S)
        deadline = clock() + 10.0
        while clock() < deadline and any(
                j.first_started_at is None for j in victims):
            time.sleep(0.005)
    finally:
        end_wall = time.time()
        service.close()
    delays = [(j.queue_delay if j.queue_delay is not None
               else end_wall - j.created_at) for j in victims]
    return percentiles(delays)


def rows_victim_isolation() -> List[Tuple[str, float, str]]:
    # the victim is the interactive tier: its 10× weight means that while
    # it is backlogged a whole mini-burst drains before one hostile job
    # interleaves, so its delay under attack stays within one hostile
    # job's service time of the isolated run
    reg = TenantRegistry.parse("victim:weight=10,hostile:weight=1")
    runs = (
        ("isolated", ShardedQueueManager(reg, quantum=QUANTUM), False),
        ("dwrr", ShardedQueueManager(reg, quantum=QUANTUM), True),
        ("blind", QueueManager(), True),
    )
    p95: Dict[str, float] = {}
    out = []
    for mode, queue, hostile in runs:
        pct = _victim_run(queue, hostile)
        p95[mode] = pct["p95"]
        out.append((f"tenant_fairness/victim_p95_{mode}",
                    pct["p95"] * 1e6,
                    f"p50={pct['p50'] * 1e3:.2f}ms;"
                    f"p95={pct['p95'] * 1e3:.2f}ms;"
                    f"hostile_backlog_items={HOSTILE_JOBS * JOB_ITEMS}"
                    if hostile else
                    f"p50={pct['p50'] * 1e3:.2f}ms;"
                    f"p95={pct['p95'] * 1e3:.2f}ms;hostile=none"))
    iso = max(p95["isolated"], 1e-9)
    out.append(("tenant_fairness/victim_p95_ratio_dwrr_vs_isolated",
                (p95["dwrr"] / iso) * 1e6,
                f"ratio={p95['dwrr'] / iso:.2f}x;target<=2x"))
    out.append(("tenant_fairness/victim_p95_ratio_blind_vs_isolated",
                (p95["blind"] / iso) * 1e6,
                f"ratio={p95['blind'] / iso:.2f}x;unbounded_with_backlog"))
    return out


def rows_tenant_fairness() -> List[Tuple[str, float, str]]:
    return (rows_weighted_fairness() + rows_offered_load()
            + rows_victim_isolation())


ALL = [rows_tenant_fairness]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows_tenant_fairness():
        print(f"{name},{us:.3f},{derived}")
