"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  The simulator figures are exact
reproductions of the paper's experiment grid (calibration in
repro/core/platforms.py); `realexec/` rows exercise the actual threaded
scheduler runtime on this host.

Run:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks.paper_figures import ALL as PAPER
    from benchmarks.queue_saturation import ALL as QUEUE
    print("name,us_per_call,derived")
    for fn in PAPER + QUEUE:
        for name, us, derived in fn():
            print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
