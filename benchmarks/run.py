"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; ``--json out.json`` additionally
emits machine-readable results (a list of {name, us_per_call, derived}
objects) so benchmark trajectories can be tracked across commits. The
simulator figures are exact reproductions of the paper's experiment grid
(calibration in repro/core/platforms.py); `realexec/` rows exercise the
actual threaded scheduler runtime on this host; `batch_boundary/` rows
compare the rebuild-per-batch and persistent-runtime serving drains.

``--check BENCH_N.json`` compares the run against a committed snapshot
and exits non-zero when any same-name row regresses past
``--check-tol`` × its snapshot value (floored at 5µs so nanosecond-scale
rows don't trip on scheduler jitter) — the perf-regression gate
scripts/smoke.sh runs on every change.

Run:  PYTHONPATH=src python -m benchmarks.run [--json out.json]
                                              [--only batch_boundary]
                                              [--check BENCH_9.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON to PATH")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="alias for --json (e.g. --out BENCH_6.json for "
                         "a committed per-PR benchmark record)")
    ap.add_argument("--only", default=None, metavar="SUBSTRS",
                    help="run only benchmark suites whose function name "
                         "contains one of the comma-separated substrings "
                         "(e.g. batch_boundary, queue_saturation, "
                         "tenant_fairness, fig7, dispatch_overhead,"
                         "telemetry_overhead, latency_tiers, federation, "
                         "chaos_soak, realexec — or "
                         "'dispatch_overhead,telemetry_overhead')")
    ap.add_argument("--quick", action="store_true",
                    help="tiny-size smoke profile: runs only the suites "
                         "with a quick variant (dispatch_overhead, which "
                         "fails hard on an old/new schedule-result "
                         "mismatch) — wired into scripts/smoke.sh")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="compare against a committed JSON snapshot "
                         "(from --out) and exit 1 if any overlapping row "
                         "regresses past --check-tol × its snapshot "
                         "us_per_call")
    ap.add_argument("--check-tol", type=float, default=3.0,
                    metavar="FACTOR",
                    help="regression tolerance factor for --check "
                         "(default 3.0; snapshot values floored at 5µs)")
    args = ap.parse_args()

    from benchmarks.adaptive_policy import ALL as ADAPTIVE, \
        QUICK as ADAPTIVE_QUICK
    from benchmarks.batch_boundary import ALL as BOUNDARY
    from benchmarks.chaos_soak import ALL as CHAOS
    from benchmarks.dispatch_overhead import ALL as DISPATCH, \
        QUICK as DISPATCH_QUICK
    from benchmarks.federation import ALL as FEDERATION, \
        QUICK as FEDERATION_QUICK
    from benchmarks.latency_tiers import ALL as LATENCY
    from benchmarks.paper_figures import ALL as PAPER
    from benchmarks.queue_saturation import ALL as QUEUE
    from benchmarks.telemetry_overhead import ALL as TELEMETRY, \
        QUICK as TELEMETRY_QUICK
    from benchmarks.tenant_fairness import ALL as TENANT

    everything = PAPER + QUEUE + BOUNDARY + TENANT + DISPATCH \
        + TELEMETRY + LATENCY + ADAPTIVE + FEDERATION + CHAOS
    if args.quick:
        everything = DISPATCH_QUICK + TELEMETRY_QUICK + ADAPTIVE_QUICK \
            + FEDERATION_QUICK
    wanted = [s.strip() for s in args.only.split(",") if s.strip()] \
        if args.only else []
    suites = [fn for fn in everything
              if not wanted or any(s in fn.__name__ for s in wanted)]
    if args.only and not suites:
        names = ", ".join(fn.__name__ for fn in everything)
        ap.error(f"--only {args.only!r} matches no suite; available: "
                 f"{names}")
    rows = []
    print("name,us_per_call,derived")
    for fn in suites:
        for name, us, derived in fn():
            print(f"{name},{us:.3f},{derived}")
            rows.append({"name": name, "us_per_call": round(us, 3),
                         "derived": derived})
    out_path = args.out or args.json
    if out_path:
        with open(out_path, "w", encoding="utf-8") as fh:
            json.dump(rows, fh, indent=2)
            fh.write("\n")
    if args.check:
        _check(rows, args.check, args.check_tol, ap)


def _check(rows, snap_path, tol, ap) -> None:
    """Tolerance-based perf-regression gate against a committed snapshot.
    Only same-name rows are compared (a snapshot from a different profile
    simply has no overlap and is an error); derived-ratio rows (names
    containing "speedup") are skipped — their us column is a ratio, not a
    cost, and a *higher* value is better."""
    with open(snap_path, encoding="utf-8") as fh:
        base = {r["name"]: float(r["us_per_call"]) for r in json.load(fh)}
    overlap = [r for r in rows
               if r["name"] in base and "speedup" not in r["name"]]
    if not overlap:
        ap.error(f"--check {snap_path!r}: no overlapping benchmark rows "
                 f"(snapshot from a different profile?)")
    bad = []
    for r in overlap:
        limit = tol * max(base[r["name"]], 5.0)
        if r["us_per_call"] > limit:
            bad.append(f"  {r['name']}: {r['us_per_call']:.3f}us > "
                       f"{limit:.3f}us "
                       f"(snapshot {base[r['name']]:.3f}us x tol {tol:g})")
    if bad:
        print(f"PERF REGRESSION vs {snap_path} ({len(bad)} of "
              f"{len(overlap)} rows):", file=sys.stderr)
        for line in bad:
            print(line, file=sys.stderr)
        sys.exit(1)
    print(f"perf check ok: {len(overlap)} rows within {tol:g}x of "
          f"{snap_path}")


if __name__ == "__main__":
    main()
