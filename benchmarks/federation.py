"""Federation: throughput scaling, cross-runtime fairness, kill recovery.

Three questions about the multi-runtime tier (repro.federation), all on
deterministic SleepExecutor runtimes so the numbers characterize the
federation layer — router, gossip, replication, failover — not model
compute:

  * scaling — aggregate drained throughput at 1/2/4/8 runtimes with the
    *per-runtime* offered load held fixed (each runtime brings its own
    work and its own capacity). Ideal is linear; the speedup row reports
    thr(8)/thr(1) with a ≥6× target — what bounded-load consistent-hash
    routing plus per-runtime scheduler runtimes must preserve of it once
    gossip/routing/journal-mirroring overheads are on the path.

  * fairness — a 10:1 weight skew (gold vs. free) saturating 4 runtimes:
    both tenants' jobs spread across *all* runtimes (bounded-load
    spill), each runtime's DWRR drains 10:1 locally, and the global
    weight-normalized Jain index over a fixed mid-drain window must stay
    ≥ 0.95 — weighted fairness has to survive sharding across runtimes.

  * kill recovery — 3 runtimes, one crashed mid-drain (in-flight epochs
    cancelled un-finalized, journal gone); its ring replica replays onto
    a survivor. Zero loss required: every job terminal, every victim job
    requeued — the benchmark hard-fails otherwise.

Run:  PYTHONPATH=src python -m benchmarks.run --only federation
      PYTHONPATH=src python -m benchmarks.federation
"""
from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path
from typing import List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import telemetry as telemetry_mod
from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.federation import FederatedService
from repro.queue import Job, JobService, JobState
from repro.tenancy import (ShardedQueueManager, TenantAccountant,
                           TenantRegistry)

clock = time.monotonic

RATE = 5_000.0                       # items/s per simulated runtime
JOB_ITEMS = 100


def _make_fed(n: int, directory: str, registry=None,
              rate: float = RATE, batch_jobs: int = 4,
              heartbeat_s: float = 0.05) -> FederatedService:
    """N simulated runtimes, each one accel group at ``rate`` items/s.
    SleepExecutors spend their service time in sleep, so N runtimes
    genuinely overlap under the GIL and scaling measures the federation
    layer, not the interpreter."""

    def make_service(rid, journal, telemetry):
        def make_sched():
            name = f"{rid}/accel"
            groups = {name: GroupSpec(name, DeviceKind.ACCEL,
                                      fixed_chunk=64,
                                      init_throughput=rate)}
            execs = {name: SleepExecutor(rate=rate)}
            return DynamicScheduler(groups, execs, telemetry=telemetry)

        accountant = None
        if registry is not None:
            queue = ShardedQueueManager(registry, telemetry=telemetry)
            accountant = TenantAccountant(registry)
        else:
            queue = None
        return JobService(make_sched, queue=queue, journal=journal,
                          accountant=accountant, batch_jobs=batch_jobs,
                          poll_s=0.002, telemetry=telemetry)

    rids = [f"r{i}" for i in range(n)]
    return FederatedService(make_service, rids, directory,
                            tenants=registry,
                            telemetry=telemetry_mod.OFF,
                            heartbeat_s=heartbeat_s)


# ---------------------------------------------------------------------------
# throughput scaling at fixed per-runtime offered load
# ---------------------------------------------------------------------------

def _drain_throughput(n: int, jobs_per_runtime: int) -> Tuple[float, int]:
    """items/s and job count for an n-runtime drain; each runtime's
    offered load is ``jobs_per_runtime × JOB_ITEMS`` items. Tenants span
    4× the runtime count so the ring has keys to spread."""
    fed = _make_fed(n, tempfile.mkdtemp(prefix="fedbench-"))
    n_jobs = jobs_per_runtime * n
    tenants = [f"t{i}" for i in range(4 * n)]
    jobs = [Job(items=JOB_ITEMS, tenant=tenants[i % len(tenants)])
            for i in range(n_jobs)]
    fed.start()
    t0 = clock()
    for j in jobs:
        fed.submit(j)
    ok = fed.run_until_idle(timeout_s=120.0)
    dt = clock() - t0
    fed.close()
    done = sum(1 for j in fed._jobs.values() if j.state == JobState.DONE)
    if not ok or done != n_jobs:
        raise RuntimeError(
            f"federation scale_{n}: {done}/{n_jobs} done, idle={ok}")
    return (n_jobs * JOB_ITEMS) / dt, n_jobs


def rows_scaling(jobs_per_runtime: int = 40,
                 fleet=(1, 2, 4, 8)) -> List[Tuple[str, float, str]]:
    out = []
    thr = {}
    for n in fleet:
        items_s, n_jobs = _drain_throughput(n, jobs_per_runtime)
        thr[n] = items_s
        us_per_item = 1e6 / items_s
        out.append((f"federation/scale_{n}", us_per_item,
                    f"runtimes={n};items_s={items_s:.0f};jobs={n_jobs};"
                    f"offered_per_runtime={jobs_per_runtime * JOB_ITEMS}"))
    lo, hi = min(fleet), max(fleet)
    speedup = thr[hi] / thr[lo]
    target = ";target>=6x" if hi // lo >= 8 else ""
    out.append((f"federation/scale_speedup_{lo}to{hi}", speedup * 1e6,
                f"speedup={speedup:.2f}x;ideal={hi / lo:.0f}x{target}"))
    return out


# ---------------------------------------------------------------------------
# weighted fairness spanning runtimes (10:1 skew, fixed window)
# ---------------------------------------------------------------------------

def jain_index(xs: List[float]) -> float:
    if not xs or all(x == 0.0 for x in xs):
        return 0.0
    return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))


def rows_fairness(n: int = 4,
                  window_s: float = 0.8) -> List[Tuple[str, float, str]]:
    weights = {"gold": 10.0, "free": 1.0}
    registry = TenantRegistry.parse("gold:weight=10,free:weight=1")
    fed = _make_fed(n, tempfile.mkdtemp(prefix="fedbench-"),
                    registry=registry, batch_jobs=8)
    # ~2 windows of backlog per tenant across the whole fleet, so every
    # runtime's shards stay busy through the measurement window and
    # bounded-load routing has spilled both tenants fleet-wide
    per_tenant_items = int(2.0 * window_s * n * RATE)
    for _ in range(per_tenant_items // JOB_ITEMS):
        fed.submit(Job(items=JOB_ITEMS, tenant="gold"))
        fed.submit(Job(items=JOB_ITEMS, tenant="free"))
    fed.start()
    time.sleep(window_s)
    # read the window while everything is still draining: attribution
    # counts finalized batches only, and closing runtimes sequentially
    # would let the later ones drain past the window
    items = {t: 0 for t in weights}
    spread = {t: 0 for t in weights}
    leftover = 0
    for node in fed.nodes().values():
        for t, u in node.service.accountant.snapshot().items():
            items[t] += u["items"]
            spread[t] += u["items"] > 0
        leftover += node.service.queue.backlog_items()
    fed.close()
    if leftover <= 0:
        raise RuntimeError("fairness window outlived the backlog; "
                           "grow per_tenant_items")
    xs = [items[t] / w for t, w in weights.items()]
    jain = jain_index(xs)
    total = sum(items.values())
    shares = ";".join(f"{t}={items[t] / max(total, 1):.3f}"
                      for t in weights)
    return [("federation/fairness_jain", jain * 1e6,
             f"jain={jain:.4f};{shares};skew=10:1;runtimes={n};"
             f"spread=gold@{spread['gold']}+free@{spread['free']};"
             f"target>=0.95")]


# ---------------------------------------------------------------------------
# kill-one-runtime recovery: zero loss required
# ---------------------------------------------------------------------------

def rows_kill_recovery(n: int = 3, n_jobs: int = 60,
                       rate: float = 2_000.0,
                       kill_frac: float = 0.3) \
        -> List[Tuple[str, float, str]]:
    fed = _make_fed(n, tempfile.mkdtemp(prefix="fedbench-"), rate=rate)
    tenants = [f"t{i}" for i in range(4 * n)]
    jobs = [Job(items=50, tenant=tenants[i % len(tenants)])
            for i in range(n_jobs)]
    fed.start()
    t0 = clock()
    for j in jobs:
        fed.submit(j)
    deadline = clock() + 60.0
    while clock() < deadline:
        if sum(1 for j in jobs if j.state == JobState.DONE) \
                >= kill_frac * n_jobs:
            break
        time.sleep(0.005)
    victim = "r1"
    victim_unfinished = [
        j for j in fed._jobs.values()
        if fed._placement.get(j.job_id) == victim
        and j.state not in (JobState.DONE, JobState.FAILED,
                            JobState.CANCELLED)]
    recovered = fed.kill_runtime(victim)
    ok = fed.run_until_idle(timeout_s=60.0)
    dt = clock() - t0
    fed.close()
    final = fed._jobs
    lost = [j for j in final.values() if j.state != JobState.DONE]
    missing = [j for j in victim_unfinished
               if final[j.job_id].state != JobState.DONE]
    if not ok or lost or missing:
        raise RuntimeError(
            f"federation kill_recovery lost work: idle={ok} "
            f"non_done={len(lost)} victim_missing={len(missing)}")
    total_items = sum(j.items for j in final.values())
    return [("federation/kill_recovery", dt * 1e6 / total_items,
             f"runtimes={n};killed={victim};"
             f"victim_unfinished={len(victim_unfinished)};"
             f"requeued={len(recovered)};lost=0;done={len(final)}")]


# ---------------------------------------------------------------------------

def rows_federation() -> List[Tuple[str, float, str]]:
    return rows_scaling() + rows_fairness() + rows_kill_recovery()


def rows_federation_quick() -> List[Tuple[str, float, str]]:
    """Smoke-sized profile (same row names where shapes match, so the
    committed --quick snapshot overlaps the smoke --check run)."""
    return (rows_scaling(jobs_per_runtime=20, fleet=(1, 4))
            + rows_fairness(n=2, window_s=0.4)
            + rows_kill_recovery(n=3, n_jobs=40))


ALL = [rows_federation]
QUICK = [rows_federation_quick]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows_federation():
        print(f"{name},{us:.3f},{derived}")
