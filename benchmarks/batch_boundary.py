"""Batch-boundary overhead: rebuild-per-batch vs. persistent runtime.

The paper shaves per-offload host overheads (O_td, thread wake-ups); the
serving path used to re-pay a much larger version at every *batch*
boundary — a fresh DynamicScheduler, fresh executors, and a full set of
dispatcher threads spawned and joined per batch, with a global barrier in
between. This benchmark measures that cost directly on deterministic
SleepExecutors (so the numbers characterize the runtime layer, not model
compute):

  * setup_ms   — scheduler construction + thread spawn until the first
                 token is handed out (per batch)
  * gap_ms     — inter-batch idle gap: time between batch k's last chunk
                 completion and batch k+1's first token (clamped at 0;
                 with the double-buffered drain epochs overlap and the
                 gap vanishes)
  * p95 queue delay at the same offered load (0.9 of aggregate capacity),
    rebuild-per-batch vs. persistent JobService — the headline number

Run:  PYTHONPATH=src python -m benchmarks.run            (all benchmarks)
      PYTHONPATH=src python -m benchmarks.batch_boundary
"""
from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Dict, List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (DeviceKind, DynamicScheduler, GroupSpec,
                        SleepExecutor)
from repro.queue import AdmissionController, Job, JobService, QueueManager

clock = time.monotonic

ACCEL_RATE = 20_000.0
CPU_RATE = 5_000.0
BATCHES = 8
BATCH_ITEMS = 2_000                   # ≈ 80 ms of aggregate capacity
JOB_ITEMS = 250
SLO_DELAY_S = 0.5
WINDOW_S = 1.2
LOAD = 0.9


def _specs() -> Dict[str, GroupSpec]:
    return {
        "accel": GroupSpec("accel", DeviceKind.ACCEL, fixed_chunk=512,
                           init_throughput=ACCEL_RATE),
        "cpu0": GroupSpec("cpu0", DeviceKind.BIG, init_throughput=CPU_RATE,
                          min_chunk=8),
    }


def _execs() -> Dict[str, SleepExecutor]:
    return {"accel": SleepExecutor(rate=ACCEL_RATE),
            "cpu0": SleepExecutor(rate=CPU_RATE)}


def _make_scheduler() -> DynamicScheduler:
    return DynamicScheduler(_specs(), _execs())


def _span(res) -> Tuple[float, float]:
    """(first token handed out, last chunk completed) of one batch."""
    return (min(r.tc1 for r in res.records),
            max(r.tc3 for r in res.records))


def _boundary_rebuild() -> Tuple[List[float], List[float]]:
    """Old design: fresh scheduler + threads per batch, joined in between."""
    setups, gaps = [], []
    prev_end = None
    for _ in range(BATCHES):
        t_sub = clock()
        res = _make_scheduler().run(0, BATCH_ITEMS)
        first, last = _span(res)
        setups.append(first - t_sub)
        if prev_end is not None:
            gaps.append(max(first - prev_end, 0.0))
        prev_end = last
    return setups, gaps


def _boundary_persistent() -> Tuple[List[float], List[float]]:
    """Persistent runtime, double-buffered: epoch k+1 submitted while
    epoch k is in flight, mirroring JobService's continuous drain. The
    one-time runtime start cost is amortized over all batches (a queued
    epoch's submit-to-first-token time is pipeline wait, not setup)."""
    t_start = clock()
    sched = _make_scheduler()
    sched.start()
    results = []
    handles = []
    try:
        for _ in range(BATCHES):
            handles.append(sched.submit_epoch((0, BATCH_ITEMS)))
            if len(handles) - len(results) > 1:   # keep ≤ 2 in flight
                results.append(handles[len(results)].result(timeout=30.0))
        while len(results) < len(handles):
            results.append(handles[len(results)].result(timeout=30.0))
    finally:
        sched.shutdown()
    first0 = min(r.tc1 for r in results[0].records)
    setups = [(first0 - t_start) / BATCHES] * BATCHES   # amortized
    gaps = []
    prev_end = None
    for res in results:
        first, last = _span(res)
        if prev_end is not None:
            gaps.append(max(first - prev_end, 0.0))
        prev_end = last
    return setups, gaps


def _queue_delay(persistent: bool) -> Tuple[Dict[str, float], int, int]:
    """p95 queue delay at offered load LOAD, one drain mode."""
    capacity = ACCEL_RATE + CPU_RATE
    jobs_per_s = LOAD * capacity / JOB_ITEMS
    n_jobs = max(1, int(jobs_per_s * WINDOW_S))
    gap = 1.0 / jobs_per_s

    queue = QueueManager()
    admission = AdmissionController(queue, slo_delay_s=SLO_DELAY_S)
    admission.on_group_join("accel", ACCEL_RATE)
    admission.on_group_join("cpu0", CPU_RATE)
    service = JobService(_make_scheduler, queue=queue, admission=admission,
                         batch_jobs=8, poll_s=0.002,
                         persistent=persistent,
                         pipeline_depth=2 if persistent else 1)
    service.start()
    jobs = []
    try:
        for i in range(n_jobs):
            job = Job(items=JOB_ITEMS, priority=i % 3)
            jobs.append(job)
            service.submit(job)
            time.sleep(gap)
        service.retry_deferred()
        deadline = clock() + 30.0
        while clock() < deadline:
            if queue.depth() == 0 and all(
                    j.terminal for j in jobs if j.state.value != "pending"):
                break
            time.sleep(0.01)
    finally:
        service.close()
    return (service.stats.delay_percentiles(), service.stats.done,
            service.stats.overlapped_batches())


def _ms(xs: List[float]) -> float:
    return 1e3 * sum(xs) / max(len(xs), 1)


def rows_batch_boundary():
    out = []
    for mode, fn in (("rebuild", _boundary_rebuild),
                     ("persistent", _boundary_persistent)):
        setups, gaps = fn()
        derived = (f"setup_ms={_ms(setups):.3f};gap_ms={_ms(gaps):.3f};"
                   f"batches={BATCHES};items={BATCH_ITEMS}")
        # per-batch boundary overhead = setup + idle gap, in µs
        us = 1e6 * (sum(setups) + sum(gaps)) / BATCHES
        out.append((f"batch_boundary/{mode}", us, derived))
    for mode, persistent in (("rebuild", False), ("persistent", True)):
        pct, done, overlapped = _queue_delay(persistent)
        derived = (f"p50={pct['p50'] * 1e3:.2f}ms;"
                   f"p95={pct['p95'] * 1e3:.2f}ms;"
                   f"p99={pct['p99'] * 1e3:.2f}ms;"
                   f"done={done};overlapped={overlapped};load={LOAD:g}")
        out.append((f"batch_boundary/queue_delay_{mode}",
                    pct["p95"] * 1e6, derived))
    return out


ALL = [rows_batch_boundary]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows_batch_boundary():
        print(f"{name},{us:.3f},{derived}")
